"""Async serving front-end: deadline/coalescing semantics, determinism.

Every test here runs under a manual-advance ``FakeClock`` (or no clock
dependence at all): time moves only when the test says so, the flusher
wakes deterministically, and there is not a single real ``sleep`` in the
file.  Each async body is wrapped in ``asyncio.wait_for`` so a hung event
loop fails the test instead of hanging CI (the tier-1 job adds a process-
level ``timeout`` on top).

The headline contract, proven several ways below (including a
property-based interleaving sweep): words delivered through the async
front-end are bit-identical per tenant to the sync ``gang=False`` solo
path, no matter how requests coalesce, interleave across coroutines and
threads, get cancelled, or straddle a snapshot.
"""
import asyncio
import concurrent.futures
import random
import threading
import time

import numpy as np
import pytest

from _propshim import given, settings, strategies as st
from repro.core.dse import Candidate
from repro.serve.async_frontend import AsyncOscillatorFarm
from repro.serve.clock import FakeClock, SystemClock
from repro.serve.farm import OscillatorFarm

from test_kernels import _mk

CAND = Candidate(i_dim=3, h_dim=8, p=1, compute_unit="vpu",
                 dtype_bytes=4, unroll=4, t_block=64)
TEST_TIMEOUT = 120.0      # hard per-test guard: a hung loop fails, not hangs


def _run(coro):
    asyncio.run(asyncio.wait_for(coro, TEST_TIMEOUT))


def _params(key=0):
    w1, b1, w2, b2, _ = _mk(3, 8, 1, key=key)
    return {"w1": w1, "b1": b1, "w2": w2, "b2": b2}


def _farm(gang=True, n_cores=3, clients=("t",), clock=None, **kw):
    farm = OscillatorFarm(gang=gang, clock=clock, **kw)
    for i in range(n_cores):
        farm.add_core(f"core{i}", _params(key=10 + i), config=CAND,
                      lanes_per_client=128, backend="pallas_interpret")
        for j, c in enumerate(clients):
            farm.register(f"core{i}", c, seed=40 + j)
    return farm


# ---------------------------------------------------------------------------
# Deadline semantics (FakeClock, zero sleeps)
# ---------------------------------------------------------------------------

def test_deadline_fires_at_deadline_not_before():
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            fut = af.submit("core0", "t", 100, deadline_ms=50)
            await af.drain()
            assert not fut.done() and farm.launches == 0
            fc.advance(0.049)                      # 1 ms short
            await af.drain()
            assert not fut.done() and farm.launches == 0
            fc.advance(0.001)                      # exactly at the deadline
            await af.drain()
            assert fut.done() and farm.launches == 1
            assert fut.result().size == 100
    _run(go())


def test_batch_flushes_before_deadline_at_auto_flush_rows():
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc,
                                       auto_flush_rows=4) as af:
            f1 = af.submit("core0", "t", 100, deadline_ms=1000)   # 1 row
            await af.drain()
            assert not f1.done()                   # below threshold, waits
            f2 = af.submit("core1", "t", 600, deadline_ms=1000)   # +5 rows
            await af.drain()
            # threshold reached: both served NOW, deadline 1 s away
            assert f1.done() and f2.done()
            assert fc.now() == 0.0
            assert farm.launches == 1
            stats = af.deadline_stats()
            assert stats["max_miss_ms"] == 0.0     # nobody missed
    _run(go())


def test_n_coalescing_tenants_one_gang_launch():
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc, n_cores=4, clients=("a", "b"))
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            futs = [af.submit(f"core{i}", c, 64 + 16 * i, deadline_ms=20)
                    for i in range(4) for c in ("a", "b")]
            await af.drain()
            assert farm.launches == 0
            fc.advance(0.02)
            await af.drain()
            assert all(f.done() for f in futs)
            # 8 tenants on 4 gang-compatible cores: ONE stacked launch
            assert farm.launches == 1
            assert farm.gang_launches == 1
    _run(go())


def test_no_deadline_means_next_pass():
    """``deadline_ms=None`` with no default: served at the next flusher
    pass, without any clock advance."""
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            out = await af.draw("core0", "t", 37)
            assert out.size == 37
            assert fc.now() == 0.0
    _run(go())


def test_rider_requests_flush_with_the_due_one():
    """A flush serves EVERY queued request, not just the due one — riders
    amortize the launch the deadline paid for."""
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            early = af.submit("core0", "t", 64, deadline_ms=10)
            late = af.submit("core1", "t", 64, deadline_ms=10_000)
            fc.advance(0.01)
            await af.drain()
            assert early.done() and late.done()
            assert farm.launches == 1
    _run(go())


# ---------------------------------------------------------------------------
# Bit-identity to the sync solo path
# ---------------------------------------------------------------------------

def test_async_words_bit_identical_to_solo():
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc, n_cores=3, clients=("a", "b"))
        results = {}
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            futs = {(f"core{i}", c): af.submit(f"core{i}", c, 100 + 31 * i,
                                               deadline_ms=5)
                    for i in range(3) for c in ("a", "b")}
            fc.advance(0.005)
            await af.drain()
            results.update({k: f.result() for k, f in futs.items()})
            # second round exercises buffered overdraw from the first
            futs = {(f"core{i}", c): af.submit(f"core{i}", c, 77,
                                               deadline_ms=5)
                    for i in range(3) for c in ("a", "b")}
            fc.advance(0.005)
            await af.drain()
            round2 = {k: f.result() for k, f in futs.items()}
        solo = _farm(gang=False, n_cores=3, clients=("a", "b"))
        for (core, c), words in results.items():
            np.testing.assert_array_equal(
                words, solo.draw(core, c, words.size))
        for (core, c), words in round2.items():
            np.testing.assert_array_equal(words, solo.draw(core, c, 77))
    _run(go())


def test_cancelled_future_rolls_demand_back():
    """A cancelled queued future never reaches the farm: co-tenants' and
    the same tenant's later words match a solo farm that never saw it."""
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            doomed = af.submit("core0", "t", 500, deadline_ms=100)
            keeper = af.submit("core1", "t", 200, deadline_ms=100)
            assert af.pending_requests == 2
            doomed.cancel()
            assert af.pending_requests == 1
            fc.advance(0.1)
            await af.drain()
            assert keeper.done() and doomed.cancelled()
            later = await af.draw("core0", "t", 90)
        solo = _farm(gang=False)
        np.testing.assert_array_equal(keeper.result(),
                                      solo.draw("core1", "t", 200))
        # solo never requested the cancelled 500 for core0 either
        np.testing.assert_array_equal(later, solo.draw("core0", "t", 90))
    _run(go())


def test_sync_pending_and_outbox_words_survive_async_flush():
    """An async flush that also serves sync-surface demand re-parks those
    words (pre-existing service pending + outbox backlog) instead of
    swallowing them: the next sync flush returns them, bit-identically."""
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc, clients=("t", "s"))
        farm.request("core0", "s", 150)            # sync tenant, un-flushed
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            mine = await af.draw("core0", "t", 220)
            assert af.pending_requests == 0
        sync_out = farm.flush()                    # launch-free delivery
        solo = _farm(gang=False, clients=("t", "s"))
        np.testing.assert_array_equal(mine, solo.draw("core0", "t", 220))
        np.testing.assert_array_equal(sync_out["core0"]["s"],
                                      solo.draw("core0", "s", 150))
    _run(go())


def test_flusher_survives_flush_failure():
    """A failing farm flush fails THAT batch's futures (nobody hangs) and
    the flusher keeps serving; the failed batch's demand — already in the
    farm — surfaces on the sync outbox, keeping streams consistent."""
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            orig = farm.flush

            def boom(*a, **kw):
                raise RuntimeError("injected launch failure")

            farm.flush = boom
            doomed = af.submit("core0", "t", 10, deadline_ms=0)
            await af.drain()
            assert isinstance(doomed.exception(), RuntimeError)
            assert len(af.flush_errors) == 1
            farm.flush = orig
            after = await af.draw("core0", "t", 20)
        sync_out = farm.flush()                 # the orphaned 10 words
        solo = _farm(gang=False)
        orphan = solo.draw("core0", "t", 10)
        np.testing.assert_array_equal(sync_out["core0"]["t"], orphan)
        np.testing.assert_array_equal(after, solo.draw("core0", "t", 20))
    _run(go())


def test_partial_flush_failure_drops_no_absorbed_words():
    """If a later group's launch fails mid-flush, words already absorbed
    for earlier groups are parked on the sync surface — not lost with the
    in-flight return value — and every stream stays gap-free."""
    cand16 = Candidate(i_dim=3, h_dim=16, p=1, compute_unit="vpu",
                       dtype_bytes=4, unroll=4, t_block=64)

    def two_group_farm(gang=True, clock=None):
        w1, b1, w2, b2, _ = _mk(3, 16, 1, key=3)
        farm = OscillatorFarm(gang=gang, clock=clock)
        farm.add_core("a", _params(key=1), config=CAND,
                      lanes_per_client=128, backend="pallas_interpret")
        farm.add_core("b", {"w1": w1, "b1": b1, "w2": w2, "b2": b2},
                      config=cand16, lanes_per_client=128,
                      backend="pallas_interpret")
        farm.register("a", "t", seed=6)
        farm.register("b", "t", seed=6)
        return farm

    async def go():
        fc = FakeClock()
        farm = two_group_farm(clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            svc_b = farm.services["b"]
            orig = svc_b._launch

            def boom(*a, **kw):
                raise RuntimeError("core b launch failed")

            svc_b._launch = boom
            fa = af.submit("a", "t", 100, deadline_ms=0)
            fb = af.submit("b", "t", 100, deadline_ms=0)
            await af.drain()
            # whole batch failed loudly (a's group had already absorbed)
            assert isinstance(fa.exception(), RuntimeError)
            assert isinstance(fb.exception(), RuntimeError)
            svc_b._launch = orig
        out = farm.flush()            # a: parked words; b: retried pending
        solo = two_group_farm(gang=False)
        np.testing.assert_array_equal(out["a"]["t"], solo.draw("a", "t", 100))
        np.testing.assert_array_equal(out["b"]["t"], solo.draw("b", "t", 100))
    _run(go())


def test_draw_sync_refused_on_loop_thread():
    async def go():
        farm = _farm()
        async with AsyncOscillatorFarm(farm) as af:
            with pytest.raises(RuntimeError, match="deadlock"):
                af.draw_sync("core0", "t", 1)
    _run(go())


# ---------------------------------------------------------------------------
# Snapshot / restore with in-flight requests
# ---------------------------------------------------------------------------

def test_snapshot_quiesces_inflight_requests():
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc, n_cores=2)
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            served = await af.draw("core0", "t", 64)     # advance state
            f1 = af.submit("core0", "t", 333, deadline_ms=500)
            f2 = af.submit("core1", "t", 70, deadline_ms=500)
            await af.drain()
            snap = await af.snapshot()                   # futures in flight
            fc.advance(0.5)
            await af.drain()
            live = {"core0": f1.result(), "core1": f2.result()}
            assert served.size == 64

        # restored onto a plain SYNC farm: the in-flight demand replays
        # through flush(), bit-identical to what the live futures got
        sync = _farm(gang=False, n_cores=2)
        sync.restore(snap)
        out = sync.flush()
        np.testing.assert_array_equal(out["core0"]["t"], live["core0"])
        np.testing.assert_array_equal(out["core1"]["t"], live["core1"])

        # restored onto another front-end: quiesce is enforced, and the
        # replayed demand surfaces on ITS sync surface
        farm2 = _farm(n_cores=2)
        af2 = AsyncOscillatorFarm(farm2)
        af2.restore(snap)
        out2 = farm2.flush()
        np.testing.assert_array_equal(out2["core0"]["t"], live["core0"])
        np.testing.assert_array_equal(out2["core1"]["t"], live["core1"])
    _run(go())


def test_restore_refuses_unquiesced_frontend():
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            snap = await af.snapshot()
            fut = af.submit("core0", "t", 10, deadline_ms=10_000)
            with pytest.raises(RuntimeError, match="in-flight"):
                af.restore(snap)
            fut.cancel()
            af.restore(snap)                   # cancelled == quiesced
    _run(go())


# ---------------------------------------------------------------------------
# Thread-safe ingress (no FakeClock advances needed: immediate deadlines)
# ---------------------------------------------------------------------------

def test_threaded_ingress_draw_sync():
    fc = FakeClock()
    farm = _farm(clock=fc, n_cores=3)
    af = AsyncOscillatorFarm(farm, clock=fc).start_thread()
    try:
        results = {}

        def worker(i):
            results[i] = af.draw_sync(f"core{i}", "t", 64 + i,
                                      deadline_ms=0, timeout=TEST_TIMEOUT)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(TEST_TIMEOUT)
    finally:
        af.close()
    solo = _farm(gang=False, n_cores=3)
    for i in range(3):
        np.testing.assert_array_equal(results[i],
                                      solo.draw(f"core{i}", "t", 64 + i))


def test_draw_sync_refused_after_close():
    farm = _farm()
    af = AsyncOscillatorFarm(farm).start_thread()
    af.close()
    with pytest.raises(RuntimeError, match="not started"):
        af.draw_sync("core0", "t", 1)


def test_thread_frontend_validates_before_enqueue():
    farm = _farm()
    af = AsyncOscillatorFarm(farm).start_thread()
    try:
        with pytest.raises(KeyError, match="unknown core"):
            af.draw_sync("nope", "t", 1)
        with pytest.raises(KeyError, match="not registered"):
            af.draw_sync("core0", "nobody", 1)
    finally:
        af.close()


# ---------------------------------------------------------------------------
# Wall-clock audit: the sync farm's deferral/coalescing reads no real time
# ---------------------------------------------------------------------------

def test_sync_farm_deferral_is_wallclock_free():
    """`flush(max_wait_rows=...)` deferral and `auto_flush` coalescing are
    flush-cycle- and row-counted: under a FROZEN FakeClock (every now()
    identical) behavior is unchanged and even the profile timers — the
    only time reads left in the sync farm — accumulate exactly zero."""
    fc = FakeClock(start=123.0)
    farm = _farm(clock=fc, profile=True)
    for i in range(3):
        farm.request(f"core{i}", "t", 10)
    assert farm.flush(max_wait_rows=64) == {}      # deferred
    assert farm.launches == 0
    out = farm.flush(max_wait_rows=64)             # overdue: must launch
    assert all(out[f"core{i}"]["t"].size == 10 for i in range(3))
    assert farm.launches == 1
    assert farm.pending_rows == 0
    prof = farm.profile_stats
    assert prof["flushes"] == 2.0
    assert all(v == 0.0 for k, v in prof.items() if k != "flushes"), prof
    assert fc.now() == 123.0


# ---------------------------------------------------------------------------
# Property-based interleaving: async front-end vs sync solo, bit for bit
# ---------------------------------------------------------------------------

def _interleaving_program(rng, n_ops):
    """A random register/submit/draw/flush/snapshot/restore program.

    Tracks quiescence so snapshot/restore land on legal states (the
    front-end itself enforces restore-quiescence; flushes serve every
    queued request, so 'flush' always quiesces).
    """
    ops, outstanding, n_snaps, n_regs = [], 0, 0, 0
    for _ in range(n_ops):
        menu = ["submit", "submit", "submit", "flush", "draw", "register"]
        if outstanding == 0:
            menu.append("snapshot")
            if n_snaps:
                menu.append("restore")
        op = rng.choice(menu)
        if op == "submit":
            ops.append(("submit", rng.randrange(2), rng.randint(1, 300),
                        rng.choice([0, 5, 50])))
            outstanding += 1
        elif op == "register":
            ops.append(("register", rng.randrange(2), f"r{n_regs}"))
            n_regs += 1
        elif op in ("flush", "draw"):
            if op == "draw":
                ops.append(("submit", rng.randrange(2),
                            rng.randint(1, 300), 0))
            ops.append(("flush",))
            outstanding = 0
        elif op == "snapshot":
            ops.append(("snapshot",))
            n_snaps += 1
        else:
            ops.append(("restore", rng.randrange(n_snaps)))
    ops.append(("flush",))
    return ops


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_interleaving_matches_solo_bit_for_bit(seed):
    rng = random.Random(seed)
    program = _interleaving_program(rng, 12)

    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc, n_cores=2, clients=("a", "b"))
        solo = _farm(gang=False, n_cores=2, clients=("a", "b"))
        registered = [(f"core{i}", c) for i in range(2) for c in ("a", "b")]
        log_async = {}
        log_solo = {}
        snaps = []
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            futs = []                        # (key, future), FIFO
            pending_solo = []                # mirrored demand
            for op in program:
                if op[0] == "submit":
                    core, client = registered[op[1] % len(registered)]
                    key = (core, client)
                    futs.append((key, af.submit(core, client, op[2],
                                                deadline_ms=op[3])))
                    pending_solo.append((core, client, op[2]))
                elif op[0] == "register":
                    core = f"core{op[1]}"
                    af.register(core, op[2], seed=900 + int(op[2][1:]))
                    solo.register(core, op[2], seed=900 + int(op[2][1:]))
                    registered.append((core, op[2]))
                elif op[0] == "flush":
                    fc.advance(1.0)
                    await af.drain()
                    for key, fut in futs:
                        log_async.setdefault(key, []).append(
                            np.asarray(fut.result()))
                    futs.clear()
                    for core, client, n in pending_solo:
                        solo.request(core, client, n)
                    if pending_solo:
                        out = solo.flush()
                        for core, per in out.items():
                            for client, w in per.items():
                                log_solo.setdefault((core, client),
                                                    []).append(w)
                    pending_solo.clear()
                elif op[0] == "snapshot":
                    snaps.append((await af.snapshot(), solo.snapshot(),
                                  list(registered)))
                else:
                    a, s, regs = snaps[op[1]]
                    af.restore(a)
                    solo.restore(s)
                    registered = list(regs)
        assert set(log_async) == set(log_solo)
        for key in log_async:
            np.testing.assert_array_equal(
                np.concatenate(log_async[key]),
                np.concatenate(log_solo[key]),
                err_msg=f"stream diverged for {key} (program={program})")

    _run(go())


# ---------------------------------------------------------------------------
# Clock unit behavior
# ---------------------------------------------------------------------------

def test_fake_clock_wait_semantics():
    async def go():
        fc = FakeClock()
        ev = asyncio.Event()

        async def sleeper():
            await fc.wait(ev, 5.0)
            return fc.now()

        task = asyncio.ensure_future(sleeper())
        for _ in range(5):                        # park the waiter
            await asyncio.sleep(0)
        fc.advance(2.0)
        for _ in range(5):
            await asyncio.sleep(0)
        assert not task.done()                    # woke, re-armed
        fc.advance(3.0)
        await asyncio.wait_for(task, 1.0)
        assert task.result() == 5.0

        # event set wakes immediately regardless of fake time
        t2 = asyncio.ensure_future(fc.wait(asyncio.Event(), None))
        await asyncio.sleep(0)
        assert not t2.done()
        t2.cancel()
        await asyncio.gather(t2, return_exceptions=True)
    _run(go())


def test_system_clock_is_a_clock():
    from repro.serve.clock import Clock
    assert isinstance(SystemClock(), Clock)
    assert isinstance(FakeClock(), Clock)


# ---------------------------------------------------------------------------
# Executor offload: the loop stays live while a launch is in flight
# ---------------------------------------------------------------------------

class _GatedFlush:
    """Wrap ``farm.flush`` so each launch pass (``deliver=False``) blocks
    on a semaphore permit before running — it executes on the offload
    worker thread, so blocking it is safe and the event loop's liveness
    mid-launch becomes directly observable.  ``release()`` lets exactly
    one launch proceed (auto-re-arms for the next)."""

    def __init__(self, farm):
        self.farm = farm
        self.orig = farm.flush
        self.entered = threading.Event()
        self._sem = threading.Semaphore(0)

    def release(self):
        self._sem.release()

    def __call__(self, *a, **kw):
        if not kw.get("deliver", True):
            self.entered.set()
            assert self._sem.acquire(timeout=TEST_TIMEOUT), \
                "gated launch never released"
        return self.orig(*a, **kw)


def test_offload_keeps_loop_live_during_launch():
    """While a gated launch is in flight on the worker thread, the event
    loop still serves zero-word draws, accepts submits, and prunes
    cancellations — and none of that traffic interleaves into the
    in-flight launch (single-flight)."""
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc, n_cores=2)
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            g = _GatedFlush(farm)
            farm.flush = g
            slow = af.submit("core0", "t", 64, deadline_ms=0)
            while not af.in_flight:             # commit happened, launch live
                await asyncio.sleep(0)
            # the loop is demonstrably live mid-launch:
            z = await af.draw("core0", "t", 0)          # round-trips NOW
            assert z.size == 0 and af.in_flight
            rider = af.submit("core1", "t", 32, deadline_ms=0)
            doomed = af.submit("core0", "t", 500, deadline_ms=10_000)
            doomed.cancel()
            assert not slow.done()              # still gated
            g.release()                         # permit: the gated launch
            g.release()                         # permit: rider's own flush
            await af.drain()
            assert slow.done() and rider.done() and doomed.cancelled()
            farm.flush = g.orig
            later = await af.draw("core0", "t", 90)
            # rider arrived mid-launch => NOT folded into the in-flight
            # launch; it rode its own later flush
            assert farm.launches >= 2
        solo = _farm(gang=False, n_cores=2)
        np.testing.assert_array_equal(slow.result(),
                                      solo.draw("core0", "t", 64))
        np.testing.assert_array_equal(rider.result(),
                                      solo.draw("core1", "t", 32))
        # the cancelled 500 never reached any farm
        np.testing.assert_array_equal(later, solo.draw("core0", "t", 90))
    _run(go())


def test_offload_off_matches_offload_on_bit_for_bit():
    """offload=False pins the on-loop launch path; served words must be
    bit-identical between the two modes (and to solo)."""
    def serve(offload):
        out = []

        async def go():
            fc = FakeClock()
            farm = _farm(clock=fc, n_cores=2)
            async with AsyncOscillatorFarm(farm, clock=fc,
                                           offload=offload) as af:
                out.append(await af.draw("core0", "t", 200, deadline_ms=0))
                out.append(await af.draw("core1", "t", 75, deadline_ms=0))
                out.append(await af.draw("core0", "t", 130, deadline_ms=0))
        _run(go())
        return out

    a, b = serve(True), serve(False)
    solo = _farm(gang=False, n_cores=2)
    ref = [solo.draw("core0", "t", 200), solo.draw("core1", "t", 75),
           solo.draw("core0", "t", 130)]
    for wa, wb, wr in zip(a, b, ref):
        np.testing.assert_array_equal(wa, wb)
        np.testing.assert_array_equal(wa, wr)


# ---------------------------------------------------------------------------
# SLO classes shape the launch, never the words
# ---------------------------------------------------------------------------

def test_slo_latency_forbids_padded_launch():
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc, n_cores=2)
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            fa = af.submit("core0", "t", 128, deadline_ms=0, slo="latency")
            fb = af.submit("core1", "t", 128 * 40, deadline_ms=0)
            await af.drain()
            dec = farm.plan_decisions
            assert sum(dec.values()) >= 1
            # a latency tenant on a skewed group: padded group-max (which
            # would make core0 wait out core1's 40 rows) is off the table
            assert dec.get("padded", 0) == 0, dec
        solo = _farm(gang=False, n_cores=2)
        np.testing.assert_array_equal(fa.result(),
                                      solo.draw("core0", "t", 128))
        np.testing.assert_array_equal(fb.result(),
                                      solo.draw("core1", "t", 128 * 40))
    _run(go())


def test_slo_bulk_forces_padded_launch():
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc, n_cores=2)
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            fa = af.submit("core0", "t", 128, deadline_ms=0, slo="bulk")
            fb = af.submit("core1", "t", 128 * 40, deadline_ms=0, slo="bulk")
            await af.drain()
            dec = farm.plan_decisions
            assert dec.get("padded", 0) == sum(dec.values()) >= 1, dec
            # with this much skew the free planner would NOT pick padded:
            # the bulk class forced it, and the farm counts that
            assert farm.slo_forced["bulk"] >= 1
        solo = _farm(gang=False, n_cores=2)
        np.testing.assert_array_equal(fa.result(),
                                      solo.draw("core0", "t", 128))
        np.testing.assert_array_equal(fb.result(),
                                      solo.draw("core1", "t", 128 * 40))
    _run(go())


def test_slo_validated_at_submit():
    async def go():
        farm = _farm()
        async with AsyncOscillatorFarm(farm) as af:
            with pytest.raises(ValueError, match="slo"):
                af.submit("core0", "t", 8, slo="gold-tier")
    _run(go())


# ---------------------------------------------------------------------------
# Satellite regressions: front-end lifecycle bugs
# ---------------------------------------------------------------------------

def test_draw_sync_timeout_prunes_queued_request():
    """S1: a timed-out draw_sync must not leak its request — the queued
    future is cancelled, the demand never reaches the farm, and the
    admission gauge is released (FakeClock: the flush deadline is far in
    fake-future, so without the fix the request would sit forever)."""
    from repro.serve.admission import AdmissionController
    fc = FakeClock()
    farm = _farm(clock=fc)
    ac = AdmissionController(max_queued_rows=2, clock=fc)
    af = AsyncOscillatorFarm(farm, clock=fc, admission=ac).start_thread()
    try:
        with pytest.raises(concurrent.futures.TimeoutError):
            af.draw_sync("core0", "t", 256, deadline_ms=10_000, timeout=0.05)
        # the prune is prompt (the timeout path wakes the flusher): the
        # gauge frees without any fake-time advance
        deadline = time.monotonic() + TEST_TIMEOUT
        while ac.queued_rows and time.monotonic() < deadline:
            time.sleep(0.002)
        assert ac.queued_rows == 0
        assert af.pending_requests == 0
        # and the farm never saw the demand: next words match a solo farm
        # that never had the timed-out request
        out = af.draw_sync("core0", "t", 64, deadline_ms=0,
                           timeout=TEST_TIMEOUT)
    finally:
        af.close()
    solo = _farm(gang=False)
    np.testing.assert_array_equal(out, solo.draw("core0", "t", 64))


def test_draw_sync_timeout_after_commit_reparks_words():
    """S1 (committed half): once the flush committed the request, it can't
    be un-launched — on timeout its words are re-parked on the sync
    surface instead of stranding in a future nobody reads."""
    fc = FakeClock()
    farm = _farm(clock=fc)
    af = AsyncOscillatorFarm(farm, clock=fc).start_thread()
    g = _GatedFlush(farm)
    try:
        farm.flush = g
        with pytest.raises(concurrent.futures.TimeoutError):
            # deadline 0: the flusher commits + launches immediately; the
            # gate holds the launch past our real-time wait
            af.draw_sync("core0", "t", 150, deadline_ms=0, timeout=0.5)
        assert g.entered.is_set()          # the request WAS committed
        g.release()
        deadline = time.monotonic() + TEST_TIMEOUT
        while (farm.services["core0"].outbox_words("t") < 150
               and time.monotonic() < deadline):
            time.sleep(0.005)
        assert farm.services["core0"].outbox_words("t") == 150
    finally:
        farm.flush = g.orig
        af.close()
    out = farm.flush()                     # launch-free outbox delivery
    solo = _farm(gang=False)
    np.testing.assert_array_equal(out["core0"]["t"],
                                  solo.draw("core0", "t", 150))


def test_flush_now_before_start_raises_cleanly():
    """S2: flush_now() on a never-started front-end must refuse up front —
    not half-run (ingest + farm.flush) and then crash on the missing
    loop."""
    async def go():
        farm = _farm()
        af = AsyncOscillatorFarm(farm)
        with pytest.raises(RuntimeError, match="not started"):
            await af.flush_now()
        assert farm.launches == 0          # nothing half-ran
        async with af:                     # still perfectly startable
            out = await af.draw("core0", "t", 16)
            assert out.size == 16
    _run(go())


def test_stats_and_error_windows_are_bounded():
    """S3: a long-running front-end must hold constant memory — miss
    samples and flush errors are ring buffers, and deadline_stats()
    reports the window, not all-time."""
    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc, stats_window=4,
                                       error_window=2) as af:
            words = []
            for _ in range(7):
                words.append(await af.draw("core0", "t", 8, deadline_ms=0))
            assert len(af.miss_samples_ms()) == 4          # not 7
            assert af.deadline_stats()["served_requests"] == 4.0
            orig = farm.flush

            def boom(*a, **kw):
                raise RuntimeError("injected")

            farm.flush = boom
            for _ in range(3):
                f = af.submit("core0", "t", 8, deadline_ms=0)
                await af.drain()
                assert isinstance(f.exception(), RuntimeError)
            assert len(af.flush_errors) == 2               # not 3
            farm.flush = orig
    _run(go())


def test_submit_refused_from_foreign_thread():
    """S4: submit() from a non-loop thread used to race the queue
    unsynchronized and silently corrupt state; now it raises the same
    clear redirect draw_sync gives on the loop thread."""
    farm = _farm()
    af = AsyncOscillatorFarm(farm).start_thread()
    try:
        with pytest.raises(RuntimeError, match="draw_sync"):
            af.submit("core0", "t", 8, deadline_ms=0)
        # the supported cross-thread path still works
        out = af.draw_sync("core0", "t", 8, deadline_ms=0,
                           timeout=TEST_TIMEOUT)
        assert out.size == 8
    finally:
        af.close()


# ---------------------------------------------------------------------------
# Property-based: mid-launch submits/cancels under offload, bit for bit
# ---------------------------------------------------------------------------

@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=9_999))
def test_offload_midlaunch_interleaving_matches_solo(seed):
    """Random schedules where submits and cancels land WHILE a gated
    launch is in flight on the executor: per-tenant streams must stay
    bit-identical to the sync gang=False solo path — mid-launch arrivals
    ride the next cycle, cancels prune cleanly, nothing interleaves."""
    rng = random.Random(seed)

    async def go():
        fc = FakeClock()
        farm = _farm(clock=fc, n_cores=2, clients=("a", "b"))
        solo = _farm(gang=False, n_cores=2, clients=("a", "b"))
        tenants = [(f"core{i}", c) for i in range(2) for c in ("a", "b")]
        log_async = {}
        log_solo = {}
        g = _GatedFlush(farm)
        async with AsyncOscillatorFarm(farm, clock=fc) as af:
            farm.flush = g

            def submit_some(cancellable):
                batch = []
                for k, (core, c) in enumerate(
                        rng.sample(tenants, rng.randint(1, 4))):
                    n = rng.randint(1, 300)
                    f = af.submit(core, c, n, deadline_ms=0)
                    if cancellable and k > 0 and rng.random() < 0.35:
                        f.cancel()         # never reaches any farm
                    else:
                        batch.append((core, c, f, n))
                return batch

            batch = submit_some(cancellable=False)
            for _ in range(rng.randint(2, 3)):
                while not af.in_flight:     # the batch's launch is gated
                    await asyncio.sleep(0)
                # mid-launch traffic lands now, against a live loop
                next_batch = submit_some(cancellable=True)
                g.release()
                for core, c, f, n in batch:
                    log_async.setdefault((core, c), []).append(
                        np.asarray(await f))
                # mirror ONLY the committed batch into solo, same order
                for core, c, f, n in batch:
                    solo.request(core, c, n)
                out = solo.flush()
                for core, per in out.items():
                    for c, w in per.items():
                        log_solo.setdefault((core, c), []).append(w)
                batch = next_batch
            g.release()                     # final batch's launch
            for core, c, f, n in batch:
                log_async.setdefault((core, c), []).append(
                    np.asarray(await f))
            for core, c, f, n in batch:
                solo.request(core, c, n)
            out = solo.flush()
            for core, per in out.items():
                for c, w in per.items():
                    log_solo.setdefault((core, c), []).append(w)
            farm.flush = g.orig
        assert set(log_async) == set(log_solo)
        for key in log_async:
            np.testing.assert_array_equal(
                np.concatenate(log_async[key]),
                np.concatenate(log_solo[key]),
                err_msg=f"stream diverged for {key} (seed={seed})")

    _run(go())
