"""Crash recovery: the flush journal replays streams bit-exactly.

The harsh contract under test: a process serving tenants through the
async front-end dies *between* flushes (queued-but-unflushed demand dies
with it, exactly like a deadline timeout), a new process rebuilds the
same farm from weights + journal alone — no crashed-process memory — and
every tenant stream continues bit-identically to an uncrashed reference,
including words that were generated but still undelivered at the kill
point (service buffer + outbox backlog).
"""
import json

import numpy as np
import pytest

from repro.serve.async_frontend import AsyncOscillatorFarm
from repro.serve.clock import FakeClock
from repro.serve.farm import OscillatorFarm
from repro.serve.journal import FlushJournal, read_journal, replay_journal

from test_async_frontend import CAND, _farm, _params, _run


def _collect(coro):
    """Run ``coro`` under the suite's hang guard and return its result."""
    box = []

    async def wrap():
        box.append(await coro)

    _run(wrap())
    return box[0]


def _bare_farm(n_cores=2, clock=None, gang=True):
    """Same cores as ``_farm`` but NO clients registered — registration is
    the journal's job on the recovery path."""
    farm = OscillatorFarm(gang=gang, clock=clock)
    for i in range(n_cores):
        farm.add_core(f"core{i}", _params(key=10 + i), config=CAND,
                      lanes_per_client=128, backend="pallas_interpret")
    return farm


# ---------------------------------------------------------------------------
# The headline: kill between flushes, replay, continue bit-exactly
# ---------------------------------------------------------------------------

def test_kill_between_flushes_replays_bit_exact(tmp_path):
    jpath = tmp_path / "farm.journal"
    delivered = {}

    async def serve_until_kill():
        fc = FakeClock()
        farm = _bare_farm(clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc,
                                       journal=jpath) as af:
            for i in range(2):
                af.register(f"core{i}", "t", seed=40)
            af.register("core0", "s", seed=41)
            # flush 1: 200 % 128 != 0 leaves buffered overdraw (buf > 0 at
            # the journaled boundary — the replay must regenerate it)
            delivered["d1"] = await af.draw("core0", "t", 200, deadline_ms=0)
            # flush 2 also serves sync-surface demand for "s": those words
            # re-park into the outbox => outbox > 0 at the boundary too
            farm.request("core0", "s", 150)
            delivered["d2"] = await af.draw("core0", "t", 100, deadline_ms=0)
            delivered["d3"] = await af.draw("core1", "t", 64, deadline_ms=0)
            # the kill window: queued demand that never reached a flush —
            # it dies with the process and must NOT appear after recovery
            af.submit("core0", "t", 999, deadline_ms=10_000)

    _run(serve_until_kill())

    # ---- recovery: fresh process, same weights, zero crashed-state ----
    farm2 = _bare_farm()
    info = replay_journal(farm2, jpath)
    assert info["flushes"] == 3
    assert info["clients"] == 3
    assert info["rows_replayed"] > 0
    assert info["torn_tail"] is False

    # undelivered tail was rebuilt, not dropped:
    svc0 = farm2.services["core0"]
    assert len(svc0.clients["t"].buf) > 0          # buffered overdraw
    assert svc0.outbox_words("s") == 150           # parked sync words

    # reference: an uncrashed solo farm that served exactly the DELIVERED
    # draws (never the killed 999-word request)
    solo = _farm(gang=False, n_cores=2, clients=("t", "s"))
    np.testing.assert_array_equal(delivered["d1"],
                                  solo.draw("core0", "t", 200))
    np.testing.assert_array_equal(delivered["d2"],
                                  solo.draw("core0", "t", 100))
    np.testing.assert_array_equal(delivered["d3"],
                                  solo.draw("core1", "t", 64))

    # the parked outbox words surface on the recovered sync surface,
    # bit-identical to the solo stream
    out = farm2.flush()
    np.testing.assert_array_equal(out["core0"]["s"],
                                  solo.draw("core0", "s", 150))
    # and every stream CONTINUES bit-exactly past the kill point
    np.testing.assert_array_equal(farm2.draw("core0", "t", 120),
                                  solo.draw("core0", "t", 120))
    np.testing.assert_array_equal(farm2.draw("core1", "t", 77),
                                  solo.draw("core1", "t", 77))


def test_recovered_process_keeps_journaling_same_file(tmp_path):
    """Seq numbers continue across recovery: the journal is reusable as
    the recovered process's own journal, and a SECOND crash recovers to
    the post-recovery positions."""
    jpath = tmp_path / "farm.journal"

    async def phase(register: bool, n_words: int):
        fc = FakeClock()
        farm = _bare_farm(n_cores=1, clock=fc)
        if not register:
            replay_journal(farm, jpath)
        async with AsyncOscillatorFarm(farm, clock=fc,
                                       journal=jpath) as af:
            if register:
                af.register("core0", "t", seed=40)
            return await af.draw("core0", "t", n_words, deadline_ms=0)

    first = _collect(phase(True, 90))
    assert read_journal(jpath)[1] == 1             # one flush journaled
    second = _collect(phase(False, 70))
    _, last_seq, positions, torn, _ = read_journal(jpath)
    assert last_seq == 2 and not torn
    # second recovery sees the concatenated stream position
    farm3 = _bare_farm(n_cores=1)
    replay_journal(farm3, jpath)
    solo = _farm(gang=False, n_cores=1)
    np.testing.assert_array_equal(first, solo.draw("core0", "t", 90))
    np.testing.assert_array_equal(second, solo.draw("core0", "t", 70))
    np.testing.assert_array_equal(farm3.draw("core0", "t", 55),
                                  solo.draw("core0", "t", 55))


def test_rotation_bounds_replay_and_survives_kill(tmp_path):
    """Journal rotation: after ``rotate_every`` flushes the live JSONL is
    rotated aside and the new segment opens with a full farm-snapshot
    checkpoint.  A kill AFTER the rotation boundary replays from the
    checkpoint — only the post-checkpoint flush deltas recompute (replay
    cost bounded by the window, not absolute position) — and every
    stream continues bit-identically to an uncrashed reference."""
    jpath = tmp_path / "farm.journal"
    delivered = {}
    boxes = []

    async def serve():
        fc = FakeClock()
        farm = _bare_farm(n_cores=1, clock=fc)
        j = FlushJournal(jpath, clock=fc, rotate_every=2)
        boxes.append(j)
        async with AsyncOscillatorFarm(farm, clock=fc, journal=j) as af:
            af.register("core0", "t", seed=40)
            # flush 1 + flush 2; the 2nd record triggers the rotation
            delivered["d1"] = await af.draw("core0", "t", 200, deadline_ms=0)
            delivered["d2"] = await af.draw("core0", "t", 100, deadline_ms=0)
            # flush 3 lands in the NEW segment, after the checkpoint —
            # 400 words outruns the buffered overdraw, forcing a launch
            delivered["d3"] = await af.draw("core0", "t", 400, deadline_ms=0)

    _run(serve())
    j = boxes[0]
    j.close()
    assert j.rotations == 1
    # the sealed segment is kept as an audit trail
    assert list(tmp_path.glob("farm.journal.0*"))

    farm2 = _bare_farm(n_cores=1)
    info = replay_journal(farm2, jpath)
    assert info["checkpoint_seq"] == 2
    assert info["flushes"] == 3
    row_at_kill = farm2.services["core0"].clients["t"].row
    # bounded replay: only the post-checkpoint delta recomputed
    assert 0 < info["rows_replayed"] < row_at_kill

    solo = _farm(gang=False, n_cores=1)
    for k, n in (("d1", 200), ("d2", 100), ("d3", 400)):
        np.testing.assert_array_equal(delivered[k],
                                      solo.draw("core0", "t", n))
    # undelivered tail across the checkpoint survives, and the stream
    # continues bit-exactly past the kill point
    np.testing.assert_array_equal(farm2.draw("core0", "t", 120),
                                  solo.draw("core0", "t", 120))


# ---------------------------------------------------------------------------
# Durability edge cases
# ---------------------------------------------------------------------------

def test_torn_tail_record_is_discarded(tmp_path):
    jpath = tmp_path / "farm.journal"

    async def serve():
        fc = FakeClock()
        farm = _bare_farm(n_cores=1, clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc,
                                       journal=jpath) as af:
            af.register("core0", "t", seed=40)
            return await af.draw("core0", "t", 100, deadline_ms=0)

    got = _collect(serve())
    # the crash lands mid-append: a torn, non-JSON final line
    with open(jpath, "a", encoding="utf-8") as f:
        f.write('{"type":"flush","seq":2,"cor')
    regs, last_seq, positions, torn, _ = read_journal(jpath)
    assert torn is True and last_seq == 1
    farm2 = _bare_farm(n_cores=1)
    info = replay_journal(farm2, jpath)
    assert info["torn_tail"] is True and info["flushes"] == 1
    solo = _farm(gang=False, n_cores=1)
    np.testing.assert_array_equal(got, solo.draw("core0", "t", 100))
    np.testing.assert_array_equal(farm2.draw("core0", "t", 60),
                                  solo.draw("core0", "t", 60))


def test_replay_refuses_mismatched_farm(tmp_path):
    jpath = tmp_path / "farm.journal"
    with FlushJournal(jpath, clock=FakeClock()) as j:
        j.record_register("core9", "t", seed=1)
    with pytest.raises(ValueError, match="core9"):
        replay_journal(_bare_farm(n_cores=1), jpath)


def test_replay_refuses_rewind():
    """replay_client advances forward only (from row 0 or a checkpoint):
    replaying a position BEHIND a client that already served words would
    corrupt stream state, so it refuses."""
    farm = _bare_farm(n_cores=1)
    farm.register("core0", "t", seed=40)
    farm.draw("core0", "t", 10)
    with pytest.raises(ValueError, match="rewind"):
        farm.services["core0"].replay_client("t", row=0)


def test_journal_timestamps_come_from_the_clock(tmp_path):
    fc = FakeClock(start=777.0)
    jpath = tmp_path / "farm.journal"
    with FlushJournal(jpath, clock=fc) as j:
        j.record_register("core0", "t", seed=1)
    recs = [json.loads(line)
            for line in jpath.read_text().splitlines()]
    assert all(r["ts"] == 777.0 for r in recs)


# ---------------------------------------------------------------------------
# Single-writer lock (flock + pid/host sentinel)
# ---------------------------------------------------------------------------

def test_second_writer_fails_fast_with_holder(tmp_path):
    from repro.serve.journal import JournalLocked
    jpath = tmp_path / "farm.journal"
    j1 = FlushJournal(jpath, clock=FakeClock())
    try:
        with pytest.raises(JournalLocked) as ei:
            FlushJournal(jpath, clock=FakeClock())
        # the sentinel names the live holder: pid@host
        assert f"{__import__('os').getpid()}@" in str(ei.value.holder)
    finally:
        j1.close()
    # close releases the flock: a new writer acquires cleanly
    FlushJournal(jpath, clock=FakeClock()).close()


def test_lock_released_even_when_open_fails(tmp_path, monkeypatch):
    """If __init__ dies after taking the flock (e.g. corrupt file scan),
    the lock must not leak — the next writer can still open."""
    from repro.serve.journal import JournalCorrupt, JournalLocked
    jpath = tmp_path / "farm.journal"
    with FlushJournal(jpath, clock=FakeClock()) as j:
        j.record_register("core0", "t", seed=1)
        j.record_register("core0", "u", seed=2)
    lines = jpath.read_text().splitlines()
    lines[0] = lines[0][:-5] + 'XXX"}'          # corrupt record 1 of 2
    jpath.write_text("\n".join(lines) + "\n")
    with pytest.raises(JournalCorrupt):
        FlushJournal(jpath, clock=FakeClock())
    # the failed open did not leak its flock
    with pytest.raises(JournalCorrupt):
        FlushJournal(jpath, clock=FakeClock())


# ---------------------------------------------------------------------------
# Per-record CRC: corruption pinpointed, --repair truncates to last good
# ---------------------------------------------------------------------------

def _journal_with_flushes(jpath, n_draws=3):
    async def serve():
        fc = FakeClock()
        farm = _bare_farm(n_cores=1, clock=fc)
        async with AsyncOscillatorFarm(farm, clock=fc, journal=jpath) as af:
            af.register("core0", "t", seed=40)
            out = []
            for _ in range(n_draws):
                out.append(await af.draw("core0", "t", 100, deadline_ms=0))
            return out
    return _collect(serve())


def test_midfile_corruption_raises_at_exact_record(tmp_path):
    from repro.serve.journal import JournalCorrupt
    jpath = tmp_path / "farm.journal"
    _journal_with_flushes(jpath)
    lines = jpath.read_text().splitlines()
    # flip one byte INSIDE a value of the 3rd record: still valid JSON,
    # caught only by the CRC
    bad = lines[2].replace('"core0"', '"core!"', 1)
    assert bad != lines[2]
    lines[2] = bad
    jpath.write_text("\n".join(lines) + "\n")
    with pytest.raises(JournalCorrupt) as ei:
        read_journal(jpath)
    assert ei.value.line_no == 3
    assert "--repair" in str(ei.value)
    with pytest.raises(JournalCorrupt):
        replay_journal(_bare_farm(n_cores=1), jpath)


def test_repair_truncates_to_last_good_record(tmp_path):
    from repro.serve.journal import JournalCorrupt, repair_journal
    jpath = tmp_path / "farm.journal"
    _journal_with_flushes(jpath, n_draws=3)
    lines = jpath.read_text().splitlines()
    n_total = len(lines)
    # corrupt the SECOND flush: open header, register and flush 1 survive
    lines[3] = lines[3].replace('"seq"', '"sXq"', 1)
    jpath.write_text("\n".join(lines) + "\n")
    info = repair_journal(jpath)
    assert info == {"kept": 3, "dropped": n_total - 3}
    # the repaired prefix replays: register + first flush survive
    farm2 = _bare_farm(n_cores=1)
    summary = replay_journal(farm2, jpath)
    assert summary["flushes"] == 1
    solo = _farm(gang=False, n_cores=1)
    solo.draw("core0", "t", 100)                 # skip the surviving flush
    np.testing.assert_array_equal(farm2.draw("core0", "t", 64),
                                  solo.draw("core0", "t", 64))
    # repairing an intact journal is a byte-identical no-op
    before = jpath.read_bytes()
    assert repair_journal(jpath)["dropped"] == 0
    assert jpath.read_bytes() == before


def test_repair_cli_exit_codes(tmp_path):
    from repro.serve.journal import main
    jpath = tmp_path / "farm.journal"
    _journal_with_flushes(jpath)
    assert main([str(jpath)]) == 0               # summary on a clean file
    lines = jpath.read_text().splitlines()
    lines[1] = lines[1].replace('"ts"', '"tz"', 1)
    jpath.write_text("\n".join(lines) + "\n")
    assert main([str(jpath)]) == 2               # corrupt: diagnostic exit
    assert main([str(jpath), "--repair"]) == 0
    assert main([str(jpath)]) == 0               # clean again


def test_torn_tail_still_tolerated_with_crc(tmp_path):
    """CRC must not turn the torn-tail contract into corruption: a valid
    prefix + a damaged FINAL line is a crash mid-append, not a corrupt
    journal."""
    jpath = tmp_path / "farm.journal"
    _journal_with_flushes(jpath, n_draws=1)
    with open(jpath, "a", encoding="utf-8") as f:
        f.write('{"type":"flush","seq":9,"cor')
    _, last_seq, _, torn, _ = read_journal(jpath)
    assert torn is True
    summary = replay_journal(_bare_farm(n_cores=1), jpath)
    assert summary["torn_tail"] is True
