"""Tests for the invariant linter (repro.analysis).

Three layers:
  * the fixtures corpus — one directory per rule, ``bad_*`` files
    reintroducing historical bug classes (each must be caught by exactly
    that rule) and ``good_*`` files with the blessed shape (must lint
    totally clean);
  * the suppression/baseline semantics (reason required, stale allows
    reported, subset-only gate);
  * the self-run — the repo's own tree lints clean under the committed
    baseline, which is what the CI gate enforces.
"""
import json
import pathlib
import subprocess
import sys

import pytest

from repro.analysis import analyze_text, check_baseline, run_analysis
from repro.analysis.engine import (BASELINE_NAME, baseline_from_report,
                                   repo_root)

FIXTURES = pathlib.Path(__file__).parent / "analysis_fixtures"


def lint_fixture(path: pathlib.Path):
    text = path.read_text()
    first = text.splitlines()[0]
    assert first.startswith("# lint-as: "), f"{path} missing lint-as header"
    rel = first.split("# lint-as: ", 1)[1].strip()
    return analyze_text(rel, text)


def fixture_cases(kind):
    for rule_dir in sorted(FIXTURES.iterdir()):
        for f in sorted(rule_dir.glob(f"{kind}_*.py")):
            yield pytest.param(rule_dir.name, f, id=f"{rule_dir.name}/{f.name}")


@pytest.mark.parametrize("rule,path", fixture_cases("bad"))
def test_bad_fixture_is_caught_by_its_rule(rule, path):
    report = lint_fixture(path)
    rules_hit = {f.rule for f in report.findings}
    assert rule in rules_hit, (
        f"{path.name} should trip [{rule}], got {sorted(rules_hit)}:\n"
        + "\n".join(f"  {f.line}: [{f.rule}] {f.message}"
                    for f in report.findings))


@pytest.mark.parametrize("rule,path", fixture_cases("good"))
def test_good_fixture_lints_clean(rule, path):
    report = lint_fixture(path)
    assert not report.findings, (
        f"{path.name} should be clean:\n"
        + "\n".join(f"  {f.line}: [{f.rule}] {f.message}"
                    for f in report.findings))


def test_every_rule_has_bad_and_good_fixtures():
    from repro.analysis.rules import all_rules
    for rule in all_rules():
        d = FIXTURES / rule.name
        assert list(d.glob("bad_*.py")), f"no bad fixture for {rule.name}"
        assert list(d.glob("good_*.py")), f"no good fixture for {rule.name}"


# -- suppression semantics ---------------------------------------------------

BROAD = """\
def f(x):
    try:
        return x()
    {allow}
    except Exception:
        return None
"""


def test_suppression_with_reason_suppresses():
    src = BROAD.format(
        allow="# repro: allow[broad-except] reason=errors land in the cell")
    rep = analyze_text("src/repro/launch/x.py", src)
    assert not rep.findings
    assert [f.rule for f in rep.suppressed] == ["broad-except"]
    assert rep.suppressions[0].used


def test_reasonless_allow_does_not_suppress():
    src = BROAD.format(allow="# repro: allow[broad-except]")
    rep = analyze_text("src/repro/launch/x.py", src)
    rules = sorted(f.rule for f in rep.findings)
    assert rules == ["broad-except", "suppression-hygiene"]
    assert not rep.suppressed


def test_unused_suppression_is_reported():
    src = ("# repro: allow[broad-except] reason=nothing here needs it\n"
           "X = 1\n")
    rep = analyze_text("src/repro/launch/x.py", src)
    assert [f.rule for f in rep.findings] == ["unused-suppression"]


def test_allow_in_docstring_is_not_a_suppression():
    src = ('"""Docs: write # repro: allow[broad-except] reason=... here."""\n'
           "X = 1\n")
    rep = analyze_text("src/repro/launch/x.py", src)
    assert not rep.findings and not rep.suppressions


def test_allow_covers_own_line_and_next_only():
    src = ("# repro: allow[clock-discipline] reason=fixture exercises the gap\n"
           "X = 1\n"
           "import time\n")
    rep = analyze_text("src/repro/train/x.py", src)
    # two lines below the comment: NOT covered
    assert {"clock-discipline", "unused-suppression"} <= {
        f.rule for f in rep.findings}


# -- baseline gate -----------------------------------------------------------

def test_baseline_subset_gate():
    dirty = analyze_text("src/repro/train/x.py", "import time\n")
    base = baseline_from_report(dirty)
    errors, warnings = check_baseline(dirty, base)
    assert not errors and not warnings
    # a second finding in the same file exceeds the baselined count
    dirtier = analyze_text("src/repro/train/x.py",
                           "import time\nt = time.time()\n")
    errors, _ = check_baseline(dirtier, base)
    assert errors and "clock-discipline" in errors[0]
    # and against a clean tree the stale baseline entry is a warning
    clean = analyze_text("src/repro/train/x.py", "X = 1\n")
    errors, warnings = check_baseline(clean, base)
    assert not errors and warnings


def test_baseline_flags_new_suppressions():
    clean = analyze_text("src/repro/train/x.py", "X = 1\n")
    base = baseline_from_report(clean)
    sup = analyze_text(
        "src/repro/train/x.py",
        "# repro: allow[clock-discipline] reason=testing the inventory\n"
        "import time\n")
    errors, _ = check_baseline(sup, base)
    assert errors and "allow[clock-discipline]" in errors[0]


# -- the repo itself ---------------------------------------------------------

def test_repo_lints_clean():
    report = run_analysis(repo_root())
    assert not report.findings, "\n".join(
        f"{f.path}:{f.line}: [{f.rule}] {f.message}"
        for f in report.findings)
    assert all(s.reason for s in report.suppressions)


def test_repo_matches_committed_baseline():
    root = repo_root()
    baseline = json.loads((root / BASELINE_NAME).read_text())
    errors, warnings = check_baseline(run_analysis(root), baseline)
    assert not errors, errors
    assert not warnings, warnings


def test_cli_exits_zero_on_clean_tree():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--format=json"],
        capture_output=True, text=True, cwd=repo_root(),
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["ok"] and not payload["findings"]
