"""Gang-scheduled farm launches: grouping, bit-identity, resumability.

The gang path's whole contract is "one launch per compatible group, words
bit-identical to the per-core path".  Kernel level: the stacked-weight
gang kernel must reproduce C per-core fused launches lane for lane.  Farm
level: mixed-dtype / mixed-h_dim farms must split into the right groups,
delivered words must match a ``gang=False`` farm bit for bit across
multi-flush traffic, and a snapshot taken mid-gang (requests in flight)
must replay identically — even when restored onto a farm with the other
launch mode, since chunk-invariance makes delivery independent of how
rows are batched into launches.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dse import Candidate
from repro.kernels import ops
from repro.serve.farm import OscillatorFarm, _compat_key

from test_kernels import _mk

CAND = Candidate(i_dim=3, h_dim=8, p=1, compute_unit="vpu",
                 dtype_bytes=4, unroll=4, t_block=64)


def _params(i_dim=3, h_dim=8, key=0):
    w1, b1, w2, b2, _ = _mk(i_dim, h_dim, 1, key=key)
    return {"w1": w1, "b1": b1, "w2": w2, "b2": b2}


def _stacked(param_list):
    return {k: jnp.stack([p[k] for p in param_list])
            for k in ("w1", "b1", "w2", "b2")}


# ---------------------------------------------------------------------------
# Kernel level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gang_kernel_matches_per_core(dtype):
    """One stacked launch == C per-core launches, bit for bit (words AND
    final states), including a slab referenced by two lane blocks."""
    s_block, n_steps = 128, 64
    plist = [_params(key=k) for k in range(3)]
    core_map = np.asarray([0, 2, 1, 2], np.int32)
    s_total = len(core_map) * s_block
    _, _, _, _, x0 = _mk(3, 8, s_total, key=9)
    x0 = x0.astype(dtype)
    rng = np.random.default_rng(3)
    offs = jnp.asarray(rng.integers(0, 10_000, size=s_total), np.uint32)

    gw, gs = ops.chaotic_bits_gang(
        _stacked(plist), x0, n_steps, offs, core_map=core_map,
        backend="pallas_interpret", s_block=s_block, t_block=32, unroll=2)
    assert gw.shape == (n_steps // 2, s_total)
    for g, c in enumerate(core_map):
        sl = slice(g * s_block, (g + 1) * s_block)
        w, s = ops.chaotic_bits(
            plist[c], x0[sl], n_steps, offs[sl],
            backend="pallas_interpret", s_block=s_block, t_block=32,
            unroll=2)
        np.testing.assert_array_equal(np.asarray(gw)[:, sl], np.asarray(w))
        np.testing.assert_array_equal(
            np.asarray(jnp.asarray(gs[sl], jnp.float32)),
            np.asarray(jnp.asarray(s, jnp.float32)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stacked_gang_kernel_matches_per_core(dtype):
    """The sublane-stacked layout (equal pools, one grid cell per lane
    block) is bit-identical to per-core launches too — same FMA order per
    lane, same fold, same whitening."""
    C, S, n_steps = 4, 256, 64
    plist = [_params(key=k) for k in range(C)]
    _, _, _, _, x0 = _mk(3, 8, C * S, key=6)
    x0 = x0.reshape(C, S, 3).astype(dtype)
    rng = np.random.default_rng(8)
    offs = jnp.asarray(rng.integers(0, 10_000, size=(C, S)), np.uint32)

    gw, gs = ops.chaotic_bits_gang_stacked(
        _stacked(plist), x0, n_steps, offs, backend="pallas_interpret",
        s_block=128, t_block=32, unroll=2)
    assert gw.shape == (n_steps // 2, C, S)
    for c in range(C):
        w, s = ops.chaotic_bits(plist[c], x0[c], n_steps, offs[c],
                                backend="pallas_interpret", s_block=128,
                                t_block=32, unroll=2)
        np.testing.assert_array_equal(np.asarray(gw)[:, c], np.asarray(w))
        np.testing.assert_array_equal(
            np.asarray(jnp.asarray(gs[c], jnp.float32)),
            np.asarray(jnp.asarray(s, jnp.float32)))
    # ref backend agrees with per-core ref
    rw, _ = ops.chaotic_bits_gang_stacked(
        _stacked(plist), x0, n_steps, offs, backend="ref")
    for c in range(C):
        w, _ = ops.chaotic_bits(plist[c], x0[c], n_steps, offs[c],
                                backend="ref")
        np.testing.assert_array_equal(np.asarray(rw)[:, c], np.asarray(w))


def test_stacked_gang_kernel_rejects_mxu():
    plist = [_params(key=1), _params(key=2)]
    with pytest.raises(ValueError, match="vpu"):
        ops.chaotic_bits_gang_stacked(
            _stacked(plist), jnp.zeros((2, 128, 3)), 8,
            backend="pallas_interpret", compute_unit="mxu")


def test_gang_ref_backend_matches_per_core_ref():
    """Co-simulation contract carries over: the gang 'ref' backend equals
    per-core 'ref' draws block for block."""
    s_block, n_steps = 128, 32
    plist = [_params(key=k) for k in range(2)]
    core_map = np.asarray([1, 0, 1], np.int32)
    s_total = len(core_map) * s_block
    _, _, _, _, x0 = _mk(3, 8, s_total, key=4)
    rw, rs = ops.chaotic_bits_gang(
        _stacked(plist), x0, n_steps, jnp.uint32(5), core_map=core_map,
        backend="ref", s_block=s_block)
    for g, c in enumerate(core_map):
        sl = slice(g * s_block, (g + 1) * s_block)
        w, s = ops.chaotic_bits(plist[c], x0[sl], n_steps, jnp.uint32(5),
                                backend="ref", s_block=s_block)
        np.testing.assert_array_equal(np.asarray(rw)[:, sl], np.asarray(w))
        np.testing.assert_array_equal(np.asarray(rs[sl]), np.asarray(s))


def test_gang_kernel_rejects_ragged_pool():
    plist = [_params(key=1)]
    with pytest.raises(ValueError, match="s_block multiple"):
        ops.chaotic_bits_gang(
            _stacked(plist), jnp.zeros((100, 3)), 8,
            core_map=np.asarray([0], np.int32),
            backend="pallas_interpret", s_block=128)


# ---------------------------------------------------------------------------
# Farm level
# ---------------------------------------------------------------------------

def _farm(gang, members, lanes=128, **kw):
    """members: (core, params, config, dtype) tuples."""
    farm = OscillatorFarm(gang=gang, **kw)
    for core, params, config, dtype in members:
        farm.add_core(core, params, config=config, dtype=dtype,
                      lanes_per_client=lanes, backend="pallas_interpret")
    return farm


def _compatible_members(n=4):
    return [(f"core{i}", _params(key=10 + i), CAND, None) for i in range(n)]


def test_compat_grouping_splits_mixed_farms():
    """Mixed dtype / h_dim / config cores must NOT share a gang."""
    cand16 = Candidate(i_dim=3, h_dim=16, p=1, compute_unit="vpu",
                       dtype_bytes=4, unroll=4, t_block=64)
    members = [
        ("a", _params(key=1), CAND, None),
        ("b", _params(key=2), CAND, None),                 # gangs with a
        ("c", _params(key=3), CAND, jnp.bfloat16),         # dtype differs
        ("d", _params(3, 16, key=4), cand16, None),        # h_dim differs
    ]
    farm = _farm(True, members)
    keys = {c: _compat_key(farm.services[c]) for c in farm.cores}
    assert keys["a"] == keys["b"]
    assert len({keys["a"], keys["c"], keys["d"]}) == 3

    for c in farm.cores:
        farm.register(c, "t", seed=2)
        farm.request(c, "t", 200)
    out = farm.flush()
    assert set(out) == {"a", "b", "c", "d"}
    # one gang launch for {a, b} + solo launches for c and d
    assert farm.launches == 3
    assert farm.gang_launches == 1

    # every client still gets exactly its per-core words
    solo = _farm(False, members)
    for c in solo.cores:
        solo.register(c, "t", seed=2)
        solo.request(c, "t", 200)
    ref = solo.flush()
    assert solo.launches == 4
    for c in ref:
        np.testing.assert_array_equal(out[c]["t"], ref[c]["t"])


def test_gang_vs_per_core_bit_identical_across_flushes():
    """Multi-flush, multi-client traffic: delivered words never depend on
    the launch mode (gang overdraw is buffered like batching overdraw)."""
    farms = [_farm(g, _compatible_members()) for g in (True, False)]
    for f in farms:
        for core in f.cores:
            f.register(core, "u1", seed=21)
            f.register(core, "u2", seed=22)
    traffic = [
        {"core0": [("u1", 300)], "core1": [("u2", 900)],
         "core2": [("u1", 50)], "core3": [("u2", 130)]},
        {"core0": [("u2", 411)], "core2": [("u1", 222), ("u2", 7)]},
        {"core1": [("u1", 1)], "core3": [("u1", 2048)]},
    ]
    for round_ in traffic:
        outs = []
        for f in farms:
            for core, reqs in round_.items():
                for client, n in reqs:
                    f.request(core, client, n)
            outs.append(f.flush())
        gang_out, solo_out = outs
        assert set(gang_out) == set(solo_out)
        for core in gang_out:
            assert set(gang_out[core]) == set(solo_out[core])
            for client in gang_out[core]:
                np.testing.assert_array_equal(gang_out[core][client],
                                              solo_out[core][client])
    # the whole point: far fewer launches on the gang side
    assert farms[0].launches < farms[1].launches


def test_ragged_pools_gang_via_lane_concat():
    """Cores with DIFFERENT client counts still gang (lane-concat layout
    with a per-block core-id map) and stay bit-identical to per-core."""
    members = _compatible_members(3)
    farms = [_farm(g, members) for g in (True, False)]
    for f in farms:
        f.register("core0", "only", seed=31)          # 128-lane pool
        for core in ("core1", "core2"):               # 256-lane pools
            f.register(core, "u1", seed=32)
            f.register(core, "u2", seed=33)
    for f in farms:
        f.request("core0", "only", 517)
        f.request("core1", "u2", 1024)
        f.request("core2", "u1", 64)
    gang_out, solo_out = (f.flush() for f in farms)
    assert farms[0].gang_launches == 1
    plan = next(iter(farms[0]._sched._plans.values()))
    assert plan["mode"] == "concat"                   # ragged -> lane-concat
    assert set(gang_out) == set(solo_out)
    for core in gang_out:
        for client in gang_out[core]:
            np.testing.assert_array_equal(gang_out[core][client],
                                          solo_out[core][client])
    # equal-size pools keep the cheaper sublane-stacked layout
    eq = _farm(True, _compatible_members(2))
    for core in eq.cores:
        eq.register(core, "t", seed=3)
        eq.request(core, "t", 100)
    eq.flush()
    assert next(iter(eq._sched._plans.values()))["mode"] == "stacked"


def test_gang_dispatch_cache_steady_state():
    """Steady-state traffic replays cached dispatches: distinct (group,
    bucketed rows) keys stop growing."""
    farm = _farm(True, _compatible_members())
    for core in farm.cores:
        farm.register(core, "t", seed=5)
    for _ in range(4):
        for core in farm.cores:
            # exactly one full launch worth: zero overdraw, so every round
            # replays the same bucketed row count
            farm.request(core, "t", 64 * 128)
        farm.flush()
    assert farm.gang_launches == 4
    assert farm.dispatch_misses == 1


def test_gang_snapshot_restore_mid_gang():
    """Snapshot with requests in flight, restore, flush: identical words —
    including restoring onto a farm in the OTHER launch mode."""
    farm = _farm(True, _compatible_members())
    for core in farm.cores:
        farm.register(core, "t", seed=9)
    farm.draw("core1", "t", 100)                  # advance some state first
    for core in farm.cores:
        farm.request(core, "t", 333)              # in flight at snapshot
    snap = farm.snapshot()
    a = farm.flush()

    gang2 = _farm(True, _compatible_members())
    gang2.restore(snap)
    b = gang2.flush()
    solo = _farm(False, _compatible_members())
    solo.restore(snap)
    c = solo.flush()
    assert set(a) == set(b) == set(c)
    for core in a:
        np.testing.assert_array_equal(a[core]["t"], b[core]["t"])
        np.testing.assert_array_equal(a[core]["t"], c[core]["t"])


def test_deadline_deferral_and_auto_flush():
    """Small tenants coalesce: a below-threshold group defers exactly once
    (the deadline), and auto-flush requests park words instead of losing
    them."""
    farm = _farm(True, _compatible_members())
    for core in farm.cores:
        farm.register(core, "t", seed=4)
    farm.request("core0", "t", 10)
    assert farm.flush(max_wait_rows=64) == {}     # 1 row < 64: deferred
    assert farm.launches == 0
    out = farm.flush(max_wait_rows=64)            # overdue: must launch now
    assert out["core0"]["t"].size == 10
    assert farm.launches == 1

    # a second tenant arriving lifts the group over the threshold at once
    farm.request("core0", "t", 20)
    farm.request("core1", "t", 64 * 128)          # 64 rows on its own
    out = farm.flush(max_wait_rows=64)
    assert set(out) == {"core0", "core1"}

    # auto-flush: words are parked, then delivered by the next flush
    auto = _farm(True, _compatible_members(), auto_flush_rows=4)
    solo = _farm(False, _compatible_members())
    for f in (auto, solo):
        for core in f.cores:
            f.register(core, "t", seed=4)
    auto.request("core0", "t", 100, auto_flush=True)   # 1 row < 4: waits
    assert auto.launches == 0
    auto.request("core1", "t", 600, auto_flush=True)   # 5 rows: fires
    assert auto.gang_launches == 1
    out = auto.flush()                                 # delivery only
    assert auto.launches == 1
    solo.request("core0", "t", 100)
    solo.request("core1", "t", 600)
    ref = solo.flush()
    for core in ref:
        np.testing.assert_array_equal(out[core]["t"], ref[core]["t"])
